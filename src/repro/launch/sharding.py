"""PartitionSpecs for every pytree the dry-run lowers (DESIGN.md §5).

Conventions:
  * weights shard their *fused feature* dim over ``model`` (always divisible,
    unlike head counts: hymba 25H/5KV, qwen2-vl 2KV ...);
  * embeddings/heads shard the (padded) vocab over ``model``;
  * batch shards over the data axes (``("pod","data")`` multi-pod);
  * decode KV caches shard batch over data and *sequence over model*
    (context-parallel decode — kv_heads are often < 16);
  * SSM parameters and states replicate over ``model`` (mamba2 is 130M;
    SSD head counts don't divide 16 — recorded in DESIGN.md §4);
  * the semantic-cache slab shards capacity over data (core/distributed.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models.attention import KVCache
from repro.models.model import DecodeCaches, Model
from repro.models.ssm import SSMState, ssm_dims
from repro.training.optimizer import AdamWState


def param_pspecs(config: ModelConfig, dp: tuple[str, ...]) -> dict:
    """PartitionSpec pytree mirroring Model.init_params."""
    rep = P()
    specs: dict = {"final_norm": rep}
    if config.n_codebooks > 1:
        specs["embed"] = P(None, "model", None)
        specs["lm_head"] = P(None, None, "model")
    else:
        specs["embed"] = P("model", None)
        specs["lm_head"] = P(None, "model")
    if config.n_prefix > 0:
        specs["prefix_proj"] = P(None, "model")
    if config.n_meta_tokens > 0:
        specs["meta_tokens"] = rep

    blocks: dict = {}
    if config.has_attention:
        blocks["norm1"] = rep
        blocks["wq"] = P(None, None, None, "model")
        blocks["wk"] = P(None, None, None, "model")
        blocks["wv"] = P(None, None, None, "model")
        blocks["wo"] = P(None, None, "model", None)
    if config.has_ssm:
        if not config.has_attention:
            blocks["norm1"] = rep
        blocks["ssm"] = {k: rep for k in
                         ("in_proj", "conv_w", "conv_b", "dt_bias", "a_log",
                          "d_skip", "norm_w", "out_proj")}
    model = Model(config)
    if model.n_mlp_slots > 0:
        blocks["norm2"] = rep
        blocks["mlp_gate"] = P(None, None, None, "model")
        blocks["mlp_up"] = P(None, None, None, "model")
        blocks["mlp_down"] = P(None, None, "model", None)
    if config.is_moe:
        blocks["moe_norm"] = rep
        blocks["router"] = rep
        blocks["moe_gate"] = P(None, None, None, "model")
        blocks["moe_up"] = P(None, None, None, "model")
        blocks["moe_down"] = P(None, None, "model", None)
    specs["blocks"] = blocks
    return specs


def opt_pspecs(param_specs: dict) -> AdamWState:
    """AdamW moments inherit the parameter shardings (specs are immutable,
    sharing the same pytree is safe)."""
    return AdamWState(step=P(), m=param_specs, v=param_specs)


def batch_pspecs(config: ModelConfig, shape: InputShape, dp: tuple[str, ...]):
    """Input shardings for (tokens[, prefix_emb])."""
    bspec = dp if _divisible(shape.global_batch, dp) else None
    tok = P(bspec, None, None) if config.n_codebooks > 1 else P(bspec, None)
    if config.n_prefix > 0:
        return {"tokens": tok, "prefix_emb": P(bspec, None, None)}
    return {"tokens": tok}


def _divisible(n: int, axes: tuple[str, ...], mesh=None) -> bool:
    # conservative static check against the production axis sizes
    sizes = {"pod": 2, "data": 16, "model": 16}
    total = 1
    for a in axes or ():
        total *= sizes[a]
    return axes is not None and n % total == 0 and n >= total


def decode_cache_pspecs(config: ModelConfig, batch: int, dp: tuple[str, ...],
                        quantized: bool = False) -> DecodeCaches:
    bspec = dp if _divisible(batch, dp) else None
    kv = None
    if config.has_attention:
        scale_spec = P(None, bspec, "model", None) if quantized else P()
        kv = KVCache(
            k=P(None, bspec, "model", None, None),
            v=P(None, bspec, "model", None, None),
            slot_pos=P(), pos=P(),
            k_scale=scale_spec, v_scale=scale_spec)
    ssm = None
    if config.has_ssm:
        ssm = SSMState(conv=P(None, bspec, None, None),
                       ssd=P(None, bspec, None, None, None))
    return DecodeCaches(kv=kv, ssm=ssm)


# --------------------------------------------------------------------------- #
# ShapeDtypeStruct stand-ins (no allocation — the dry-run's only inputs)
# --------------------------------------------------------------------------- #

def input_specs(config: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStructs for every model input of this (arch, shape)."""
    b, s = shape.global_batch, shape.seq_len
    out: dict = {}
    if shape.kind in ("train", "prefill"):
        text_len = s - config.n_prefix
        if config.n_codebooks > 1:
            out["tokens"] = jax.ShapeDtypeStruct(
                (b, text_len, config.n_codebooks), jnp.int32)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((b, text_len), jnp.int32)
        if config.n_prefix > 0:
            out["prefix_emb"] = jax.ShapeDtypeStruct(
                (b, config.n_prefix, config.d_model), jnp.float32)
    else:  # decode
        if config.n_codebooks > 1:
            out["tokens"] = jax.ShapeDtypeStruct(
                (b, 1, config.n_codebooks), jnp.int32)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    return out


def decode_cache_size(config: ModelConfig, shape: InputShape) -> int:
    """KV cache length for a decode shape.

    decode_32k keeps the full 32k context. long_500k uses the sub-quadratic
    variant: SSM archs have no KV at all; attention archs fall back to the
    sliding-window ring (long_context_window) — the memory-bounded design
    that makes 524k context feasible (DESIGN.md §4).
    """
    if shape.name == "long_500k":
        return min(config.long_context_window, shape.seq_len)
    return shape.seq_len


def decode_cache_specs(config: ModelConfig, shape: InputShape,
                       quantized: bool = False) -> DecodeCaches:
    """ShapeDtypeStructs for the decode caches at ``pos = seq_len - 1``."""
    b = shape.global_batch
    size = decode_cache_size(config, shape)
    dt = jnp.bfloat16 if config.dtype == "bfloat16" else jnp.float32
    kv = None
    if config.has_attention:
        kv_shape = (config.n_layers, b, size, config.n_kv_heads,
                    config.head_dim)
        kdt = jnp.int8 if quantized else dt
        sc_shape = kv_shape[:-1] if quantized else (0,)
        kv = KVCache(
            k=jax.ShapeDtypeStruct(kv_shape, kdt),
            v=jax.ShapeDtypeStruct(kv_shape, kdt),
            slot_pos=jax.ShapeDtypeStruct((size,), jnp.int32),
            pos=jax.ShapeDtypeStruct((), jnp.int32),
            k_scale=jax.ShapeDtypeStruct(sc_shape, jnp.float32),
            v_scale=jax.ShapeDtypeStruct(sc_shape, jnp.float32))
    ssm = None
    if config.has_ssm:
        dims = ssm_dims(config)
        ssm = SSMState(
            conv=jax.ShapeDtypeStruct(
                (config.n_layers, b, config.ssm_conv - 1, dims["conv_dim"]),
                jnp.float32),
            ssd=jax.ShapeDtypeStruct(
                (config.n_layers, b, dims["nheads"], dims["headdim"],
                 dims["state"]), jnp.float32))
    return DecodeCaches(kv=kv, ssm=ssm)
