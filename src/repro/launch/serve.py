"""Serving driver: ``python -m repro.launch.serve [--backend sim|model]``.

Runs the full GPT-Semantic-Cache serving system: warm the cache with the
QA corpus, stream the 2,000-test-query workload through the CachedEngine,
and print the paper's metrics. ``--backend model`` places a real (reduced)
architecture behind the cache; ``--backend sim`` uses the simulated LLM
API with the paper-style latency/cost model.

``--scheduler async`` routes the workload through the continuous
micro-batching scheduler (DESIGN.md §12) instead of the sync batch loop:
open-loop Poisson arrivals at ``--rate-qps`` (or closed-loop with
``--concurrency`` clients when no rate is given), with in-flight duplicate
coalescing; the summary then also carries p50/p95/p99 latency per path and
the coalesced-call count.

``--tenants N`` turns on multi-tenant serving (DESIGN.md §13): the slab is
partitioned into N per-tenant regions, traffic is a Zipf-skewed mixture
over the tenants (``--tenant-skew``; 0 = uniform), admission is
deficit-round-robin fair, and the summary carries per-tenant hit/miss/
latency breakdowns plus the device-side per-tenant counters.
"""
from __future__ import annotations

import argparse
import asyncio
import json

from repro.configs import get_arch
from repro.core.index import IVFIndex
from repro.core.policy import AdaptiveThreshold
from repro.core.types import CacheConfig
from repro.data.qa_dataset import build_corpus, build_test_queries
from repro.data.tokenizer import HashTokenizer
from repro.serving import (AsyncCacheServer, CachedEngine, ModelBackend,
                           Request, SchedulerConfig, SimulatedLLMBackend,
                           build_multi_tenant_workload, run_closed_loop,
                           run_open_loop)
from repro.tenancy import TenantRegistry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("sim", "model"), default="sim")
    ap.add_argument("--arch", default="yi-6b",
                    help="arch for --backend model (reduced variant)")
    ap.add_argument("--corpus", type=int, default=2000,
                    help="QA pairs per category")
    ap.add_argument("--queries", type=int, default=500,
                    help="test queries per category")
    ap.add_argument("--threshold", type=float, default=0.8)
    ap.add_argument("--ttl", type=float, default=None)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--index", choices=("exact", "ivf"), default="exact",
                    help="ANN index plugin behind the cache")
    ap.add_argument("--policy", choices=("fixed", "adaptive"), default="fixed",
                    help="threshold policy plugin")
    ap.add_argument("--no-fused", dest="fused", action="store_false",
                    help="use separate lookup+insert instead of the fused "
                         "single-jit step()")
    ap.add_argument("--scheduler", choices=("sync", "async"), default="sync",
                    help="sync batch loop vs async continuous micro-batching "
                         "with in-flight coalescing (DESIGN.md §12)")
    ap.add_argument("--max-wait-ms", type=float, default=5.0,
                    help="async admission deadline per micro-batch")
    ap.add_argument("--rate-qps", type=float, default=None,
                    help="async: open-loop Poisson arrival rate; omit for "
                         "closed-loop")
    ap.add_argument("--concurrency", type=int, default=32,
                    help="async closed-loop client count")
    ap.add_argument("--no-coalesce", dest="coalesce", action="store_false",
                    help="disable in-flight duplicate coalescing")
    ap.add_argument("--tenants", type=int, default=0,
                    help="partition the cache into N tenant regions and "
                         "serve a multi-tenant workload (0 = single-tenant)")
    ap.add_argument("--tenant-skew", type=float, default=1.0,
                    help="Zipf skew of tenant popularity (0 = uniform)")
    ap.add_argument("--snapshot", default=None,
                    help="save the full CacheRuntime (slab + policy + index "
                         "state) here after serving")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="async: serve the Prometheus-style /metrics (+ "
                         "/traces, /events) exposition on this HTTP port "
                         "for the run's duration (DESIGN.md §18.4)")
    ap.add_argument("--trace-sample", type=float, default=0.0,
                    help="request-trace retention rate in [0,1] "
                         "(0 = tracing off, the default; §18.2)")
    ap.add_argument("--trace-slow-ms", type=float, default=None,
                    help="always retain traces slower than this many ms, "
                         "even when the rate sampler would drop them")
    args = ap.parse_args()

    pairs = build_corpus(args.corpus, seed=0)
    queries = build_test_queries(pairs, n_per_category=args.queries, seed=1)
    by_id = {p.qa_id: p for p in pairs}

    def judge(req, sid):
        return sid >= 0 and sid in by_id and \
            by_id[sid].semantic_key == req.semantic_key

    if args.backend == "sim":
        backend = SimulatedLLMBackend(pairs)
    else:
        import jax
        from repro.models.model import Model
        config = get_arch(args.arch).reduced()
        model = Model(config)
        params = model.init_params(jax.random.PRNGKey(0))
        backend = ModelBackend(model, params,
                               HashTokenizer(vocab_size=config.vocab))

    registry = None
    if args.tenants > 0:
        registry = TenantRegistry.uniform(
            [f"tenant-{i}" for i in range(args.tenants)])
    # multi-tenant: every tenant's region must hold the warm corpus
    capacity = max(16384, 8 * args.corpus) * max(1, args.tenants)
    cfg = CacheConfig(dim=384, capacity=capacity,
                      value_len=48, ttl=args.ttl, threshold=args.threshold)
    index = IVFIndex(ncentroids=128, nprobe=16, bucket_cap=1024) \
        if args.index == "ivf" else None
    policy = AdaptiveThreshold(init=args.threshold) \
        if args.policy == "adaptive" else None
    tracer = None
    if args.trace_sample > 0.0 or args.trace_slow_ms is not None:
        from repro.obs import TraceConfig, Tracer
        tracer = Tracer(TraceConfig(
            sample_rate=args.trace_sample,
            slow_threshold_s=None if args.trace_slow_ms is None
            else args.trace_slow_ms / 1000.0))
    engine = CachedEngine(cfg, backend, judge=judge, batch_size=args.batch,
                          index=index, policy=policy,
                          use_fused_step=args.fused, registry=registry,
                          tracer=tracer)

    if registry is None:
        print(f"warming cache with {len(pairs)} QA pairs ...")
        engine.warm(pairs)
        requests = [Request(query=q.query, category=q.category,
                            source_id=q.source_id,
                            semantic_key=q.semantic_key) for q in queries]
    else:
        print(f"warming {args.tenants} tenant regions with "
              f"{len(pairs)} QA pairs each ...")
        for name in registry.names:
            engine.warm(pairs, tenant=name)
        requests = build_multi_tenant_workload(
            pairs, len(queries), tenants=list(registry.names),
            skew=args.tenant_skew, seed=1)
    if args.scheduler == "sync":
        print(f"serving {len(queries)} queries (sync batches) ...")
        engine.process(requests)
    else:
        mode = (f"open-loop {args.rate_qps:.0f} qps" if args.rate_qps
                else f"closed-loop x{args.concurrency}")
        print(f"serving {len(queries)} queries (async scheduler, {mode}) ...")
        # pre-trace the fused serve path, then zero the bookkeeping: the
        # one-off jit compile (~seconds) must not flood every reported
        # end-to-end percentile
        from repro.serving import ServingMetrics
        engine.serve_batch([Request(
            query="serve-path compile warmup",
            tenant="default" if registry is None else registry.names[0])])
        engine.metrics = ServingMetrics()

        async def drive():
            sched = SchedulerConfig(max_batch=args.batch,
                                    max_wait_ms=args.max_wait_ms,
                                    coalesce=args.coalesce,
                                    tenant_weights=None if registry is None
                                    else registry.weights())
            async with AsyncCacheServer(engine, sched) as server:
                if args.metrics_port is not None:
                    mport = await server.serve_metrics(
                        port=args.metrics_port)
                    print(f"/metrics exposition on "
                          f"http://127.0.0.1:{mport}/metrics")
                if args.rate_qps:
                    res = await run_open_loop(server.submit_request,
                                              requests, args.rate_qps)
                else:
                    res = await run_closed_loop(server.submit_request,
                                                requests,
                                                concurrency=args.concurrency)
            print(f"sustained {res.achieved_qps:.1f} qps "
                  f"({res.wall_s:.2f}s wall)")
        asyncio.run(drive())
        if tracer is not None and tracer.retained:
            print("trace stage decomposition (retained traces):")
            print(json.dumps(tracer.stage_decomposition(), indent=1))
    print(json.dumps(engine.metrics.summary(), indent=1))
    if registry is not None:
        print("device-side per-tenant counters:")
        print(json.dumps(engine.tenant_stats(), indent=1))
    if args.snapshot:
        engine.save_cache(args.snapshot)
        print(f"runtime snapshot (slab+policy+index state) -> {args.snapshot}")


if __name__ == "__main__":
    main()
