"""Launchers: mesh construction, multi-pod dry-run, train/serve drivers.

NOTE: ``repro.launch.dryrun`` sets XLA_FLAGS at import — import it only in
a dedicated process (``python -m repro.launch.dryrun``)."""
from repro.launch.mesh import (data_axes_of, make_local_mesh,
                               make_production_mesh, model_axes_of)

__all__ = ["make_production_mesh", "make_local_mesh", "data_axes_of",
           "model_axes_of"]
