"""Production mesh construction (DESIGN.md §5).

single-pod: (16, 16)    axes (data, model)        — 256 chips (one v5e pod)
multi-pod:  (2, 16, 16) axes (pod, data, model)   — 512 chips (2 pods)

A *function*, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax import;
tests and benches see the single real CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(n_data: int = 2, n_model: int = 2):
    """Small host-device mesh for tests (requires forced host device count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def data_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a == "model")
