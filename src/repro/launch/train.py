"""Training driver: ``python -m repro.launch.train --arch <id> [--steps N]``.

Trains a (reduced by default) architecture on the synthetic QA corpus with
AdamW + cosine schedule, periodic checkpointing, and loss logging. With
``--full`` it uses the assigned full-size config (only sensible on a real
cluster; on CPU use the default reduced variant).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data.qa_dataset import build_corpus
from repro.data.tokenizer import HashTokenizer
from repro.models.model import Model
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import AdamWConfig, adamw_update, init_adamw


def make_batches(tokenizer, pairs, batch: int, seq: int, vocab: int, seed=0):
    """Pack Q+A text into fixed-length LM training rows."""
    texts = [f"{p.question} ? {p.answer}" for p in pairs]
    toks, _ = tokenizer.encode_batch(texts, seq)
    toks = np.minimum(toks, vocab - 1)
    rng = np.random.default_rng(seed)
    while True:
        idx = rng.integers(0, len(texts), size=batch)
        yield jnp.asarray(toks[idx])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full", action="store_true",
                    help="use the full-size config (cluster only)")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    config = get_arch(args.arch)
    if not args.full:
        config = config.reduced()
    model = Model(config)
    tokenizer = HashTokenizer(vocab_size=config.vocab)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = init_adamw(params)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                       total_steps=args.steps)

    n_params = sum(int(np.prod(x.shape))
                   for x in jax.tree_util.tree_leaves(params))
    print(f"arch={config.name} params={n_params/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch} seq={args.seq}")

    @jax.jit
    def step(params, opt, tokens):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, tokens, remat=True))(params)
        params, opt, metrics = adamw_update(ocfg, params, grads, opt)
        return params, opt, loss, metrics

    batches = make_batches(tokenizer, build_corpus(500), args.batch,
                           args.seq, config.vocab)
    t0 = time.time()
    for i in range(args.steps):
        tokens = next(batches)
        params, opt, loss, metrics = step(params, opt, tokens)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(loss):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, {"params": params},
                        metadata={"arch": config.name, "steps": args.steps})
        print(f"checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
