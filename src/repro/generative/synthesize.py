"""Answer synthesis from near-hit neighbours (DESIGN.md §17.3).

A query landing in the band [τ_lo, τ_hi) has top-k neighbours that are
*similar but not identical*. Instead of discarding them (the paper's
binary miss), a ``Synthesizer`` composes an answer from their cached
responses at a fraction of full-call cost — the Generative Caching move
(arxiv 2503.17603). Two strategies:

  * ``TemplateSplice`` — pure host-side composition: serve the dominant
    neighbour's cached answer, but only when no *rival* neighbour with a
    different provenance scores within ``rival_margin`` of it. The rival
    gate is the precision mechanism: an ambiguous neighbourhood (two
    unrelated cached questions equally close) abstains back to the full
    backend call rather than guessing. Zero marginal cost and latency.

  * ``SmallModelRewrite`` — the same neighbour selection, then a rewrite
    call through the existing ``llm_backend`` abstraction (anything with
    ``generate(queries, semantic_keys) -> BackendResult``) so a small,
    cheap model adapts the cached answer to the new query's phrasing.
    Cost and latency are whatever the small backend charges — the point
    is that they are a *fraction* of the full model's.

Both are **host-side serving policy**, like the judge: the compiled step
only surfaces the band mask and the top-k payload (ids, scores, cached
responses); which answer to synthesize — or whether to abstain — never
touches device code, so strategy changes never recompile anything.

A synthesis carries the dominant neighbour's ``source_id`` as provenance:
the judge scores a near-hit against that id exactly like an exact hit,
and when the synthesized answer is admitted back into the slab (§17.4)
the entry records where its answer actually came from.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, Sequence, runtime_checkable


@dataclasses.dataclass(frozen=True)
class Neighbour:
    """One visible top-k neighbour of a near-hit query."""

    slot: int        # slab slot id
    score: float     # cosine similarity to the (possibly fused) query key
    source_id: int   # provenance of the cached entry (-1 unknown)
    answer: str      # detokenized cached response


@dataclasses.dataclass(frozen=True)
class Synthesis:
    """A composed answer + its provenance and marginal cost."""

    answer: str
    source_id: int      # dominant neighbour's provenance (judge input)
    cost_usd: float = 0.0
    latency_s: float = 0.0


@runtime_checkable
class Synthesizer(Protocol):
    """Strategy seam: neighbours -> answer, or ``None`` to abstain
    (the row then falls back to the full backend call)."""

    def synthesize(self, query: str, neighbours: Sequence[Neighbour]
                   ) -> Synthesis | None:
        ...


@dataclasses.dataclass(frozen=True)
class TemplateSplice:
    """Compose from the dominant neighbour, abstain on ambiguity.

    ``rival_margin`` is the precision knob: serve only when every
    different-provenance neighbour trails the dominant one by at least
    this much cosine. Calibrated on the hash-embedder workload
    (DESIGN.md §17.3): margin 0.12 at τ_lo=0.70 holds ~0.99 judged
    precision while converting ~half the band. Entries with unknown
    provenance (source_id < 0) always count as rivals of each other —
    abstaining on unknowns is what keeps the gate conservative.
    """

    rival_margin: float = 0.12

    def synthesize(self, query: str, neighbours: Sequence[Neighbour]
                   ) -> Synthesis | None:
        if not neighbours:
            return None
        top = max(neighbours, key=lambda nb: nb.score)
        for nb in neighbours:
            if nb is top:
                continue
            same = nb.source_id == top.source_id and top.source_id >= 0
            if not same and top.score - nb.score < self.rival_margin:
                return None                       # ambiguous neighbourhood
        return Synthesis(answer=top.answer, source_id=top.source_id)


#: Prompt scheme shared by SmallModelRewrite and SmallRewriteBackend — the
#: cached answer rides inside the prompt, separated by a sentinel, exactly
#: like a production rewrite prompt carries its context block.
_REWRITE_SEP = "\n---cached---\n"


def rewrite_prompt(query: str, cached_answer: str) -> str:
    return f"adapt the cached answer to: {query}{_REWRITE_SEP}{cached_answer}"


class SmallRewriteBackend:
    """Simulated small rewrite model behind the ``llm_backend`` interface.

    The offline stand-in for a distilled/small hosted model: it extracts
    the cached answer from the rewrite prompt and returns it (an ideal
    rewrite changes phrasing, not meaning — and our judge scores meaning
    via provenance, not bytes), charging a configurable latency and cost
    that default to ~10% of ``SimulatedLLMBackend``'s full-call numbers.
    """

    def __init__(self, *, latency_per_call_s: float = 0.08,
                 cost_per_call_usd: float = 0.0002):
        self.latency_per_call_s = latency_per_call_s
        self.cost_per_call_usd = cost_per_call_usd
        self.calls = 0

    def generate(self, queries: Sequence[str],
                 semantic_keys: Sequence[str] | None = None):
        from repro.serving.llm_backend import BackendResult
        answers = []
        for q in queries:
            _, sep, cached = q.partition(_REWRITE_SEP)
            answers.append(cached if sep else q)
        self.calls += len(queries)
        return BackendResult(
            answers=answers,
            latency_s=self.latency_per_call_s * len(queries),
            cost_usd=self.cost_per_call_usd * len(queries))


@dataclasses.dataclass(frozen=True)
class SmallModelRewrite:
    """Neighbour selection via ``TemplateSplice`` gating, answer via a
    small-model rewrite call. ``backend`` is any ``llm_backend``-shaped
    object; ``None`` constructs the simulated ``SmallRewriteBackend``."""

    backend: Any = None
    splice: TemplateSplice = TemplateSplice()

    def __post_init__(self):
        if self.backend is None:
            object.__setattr__(self, "backend", SmallRewriteBackend())

    def synthesize(self, query: str, neighbours: Sequence[Neighbour]
                   ) -> Synthesis | None:
        base = self.splice.synthesize(query, neighbours)
        if base is None:
            return None
        res = self.backend.generate([rewrite_prompt(query, base.answer)],
                                    [""])
        return Synthesis(answer=res.answers[0], source_id=base.source_id,
                         cost_usd=res.cost_usd, latency_s=res.latency_s)
