"""Generative near-hit cache: tiered threshold bands + answer synthesis
from top-k neighbours (DESIGN.md §17)."""
from repro.generative.policy import BandPolicy
from repro.generative.synthesize import (
    Neighbour,
    SmallModelRewrite,
    SmallRewriteBackend,
    Synthesis,
    Synthesizer,
    TemplateSplice,
    rewrite_prompt,
)

__all__ = [
    "BandPolicy",
    "Neighbour",
    "SmallModelRewrite",
    "SmallRewriteBackend",
    "Synthesis",
    "Synthesizer",
    "TemplateSplice",
    "rewrite_prompt",
]
