"""BandPolicy — tiered threshold bands for the generative near-hit cache
(DESIGN.md §17.2).

The paper's lookup is binary: one cosine threshold τ (0.8, §5.3) splits
hit from miss, and a query scoring 0.79 discards its top-k neighbours and
pays a full LLM call. The Generative Caching system (arxiv 2503.17603)
shows that band — *similar but not identical* — is exactly where cheap
answer synthesis from the neighbours recovers most of the remaining
backend calls. ``BandPolicy`` adds the band as a second threshold edge:

    score >= τ_hi          — exact reuse (today's hit path, unchanged)
    τ_lo <= score < τ_hi   — near-hit: surface the top-k neighbours to a
                             host-side ``Synthesizer`` (§17.3)
    score < τ_lo           — miss (full backend call)

Edge semantics are closed-open: a score exactly at τ_lo is a near-hit, a
score exactly at τ_hi is an exact hit (never both — the near mask is
defined with ``& ~hit`` at the cache level, so a per-tenant τ_hi override
moves the upper band edge automatically).

``BandPolicy`` conforms to the ``repro.core.runtime.Policy`` protocol —
``decide`` is byte-identical to ``FixedThreshold(τ_hi)``, so a band cache
with the synthesizer disabled makes exactly today's hit/miss decisions —
and adds two band-specific methods the cache discovers structurally
(``hasattr``, a trace-time constant, so band choice never recompiles or
forks the fused step):

  * ``near(scores, state)`` — the [τ_lo, τ_hi) membership mask;
  * ``update_band(state, was_positive, was_near)`` — judged near-hit
    outcomes nudge τ_lo exactly like ``AdaptiveThreshold`` nudges its
    threshold (paper §2.10; MeanCache arxiv 2403.02694 motivates learning
    the edge from hit-quality feedback): synthesis precision below target
    raises τ_lo (shrinks the band), precision above target with headroom
    lowers it to harvest more near-hits.

State layout: ``[τ_lo, τ_hi, ema_near_precision]`` (f32). τ_hi is static
— it is the paper's exact-reuse threshold, already tunable via
``AdaptiveThreshold`` if desired — only the band's lower edge adapts.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class BandPolicy:
    """Two-edge threshold band; exact path identical to FixedThreshold(τ_hi).

    Defaults calibrated on the hash-embedder workload (DESIGN.md §17.2):
    τ_lo=0.70 puts ~19% of paper-mixture queries in the band, and with the
    default ``TemplateSplice`` rival gating the synthesized answers hold
    ~0.99 judged precision — comfortably above the 0.9 acceptance bar.
    """

    tau_lo: float = 0.70
    tau_hi: float = 0.80
    # judged near-hit feedback loop (0 lr = static edges)
    target_precision: float = 0.92
    lr: float = 0.02
    ema: float = 0.9
    lo_min: float = 0.55
    min_width: float = 0.01     # τ_lo can never cross τ_hi - min_width
    # degraded-mode floor (DESIGN.md §20.4): when the backend is down and
    # the engine serves a best cached neighbour instead of failing the row,
    # this is the minimum score it may serve at. None -> the engine's
    # default floor. Always <= τ_lo — degraded serving relaxes the band's
    # lower edge, never tightens it.
    degraded_lo: float | None = None

    def __post_init__(self):
        if not (0.0 <= self.tau_lo <= self.tau_hi <= 1.0):
            raise ValueError(
                f"need 0 <= tau_lo <= tau_hi <= 1, got "
                f"({self.tau_lo}, {self.tau_hi})")
        if self.lo_min > self.tau_lo:
            raise ValueError("lo_min must not exceed tau_lo")
        if self.degraded_lo is not None:
            if not (0.0 <= self.degraded_lo <= 1.0):
                raise ValueError(
                    f"degraded_lo must lie in [0, 1], got {self.degraded_lo}")
            if self.degraded_lo > self.tau_lo:
                raise ValueError(
                    f"degraded_lo ({self.degraded_lo}) must not exceed "
                    f"tau_lo ({self.tau_lo}) — degraded serving relaxes "
                    "the band edge, never tightens it")

    # -- Policy protocol (uniform with Fixed/AdaptiveThreshold) ----------- #
    def init_state(self) -> Array:
        return jnp.asarray([self.tau_lo, self.tau_hi, self.target_precision],
                           dtype=jnp.float32)

    def decide(self, scores: Array, state: Array) -> tuple[Array, Array]:
        """Exact-reuse decision: hit iff score >= τ_hi (today's path)."""
        return scores >= state[1], state

    def update(self, state: Array, *, was_positive: Array, was_hit: Array
               ) -> Array:
        return state  # exact edge is static; the band edge adapts below

    # -- band seam (discovered via hasattr — trace-time, no recompile) ---- #
    def near(self, scores: Array, state: Array) -> Array:
        """[τ_lo, τ_hi) membership. τ_lo inclusive, τ_hi exclusive; the
        cache additionally strips hit rows (``& ~hit``), which is what
        keeps the upper edge consistent under per-tenant τ_hi overrides."""
        return (scores >= state[0]) & (scores < state[1])

    def update_band(self, state: Array, *, was_positive: Array,
                    was_near: Array) -> Array:
        """Judged synthesized-answer outcomes for a batch -> new τ_lo.

        Mirrors ``AdaptiveThreshold.update``: an EMA of near-hit precision
        tracks ``target_precision``; too many judged-negative syntheses
        raise τ_lo (shrink the band), surplus precision lowers it. Bounds:
        ``[lo_min, τ_hi - min_width]`` so the band can tighten to (almost)
        nothing but never inverts.
        """
        lo, hi, prec = state[0], state[1], state[2]
        n_near = jnp.sum(was_near.astype(jnp.float32))
        batch_prec = jnp.where(
            n_near > 0,
            jnp.sum((was_positive & was_near).astype(jnp.float32))
            / jnp.maximum(n_near, 1.0),
            prec,  # no near-hits -> no evidence
        )
        prec = self.ema * prec + (1.0 - self.ema) * batch_prec
        step = self.lr * (self.target_precision - prec)
        lo = jnp.clip(lo + step, self.lo_min, hi - self.min_width)
        return jnp.stack([lo, hi, prec])
