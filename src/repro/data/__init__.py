"""Data substrate: tokenizer, synthetic QA corpus, training pipeline."""
from repro.data.tokenizer import HashTokenizer, PAD_ID, BOS_ID, EOS_ID
from repro.data.qa_dataset import (CATEGORIES, QAPair, TestQuery,
                                   build_corpus, build_test_queries,
                                   paraphrase)

__all__ = ["HashTokenizer", "PAD_ID", "BOS_ID", "EOS_ID", "CATEGORIES",
           "QAPair", "TestQuery", "build_corpus", "build_test_queries",
           "paraphrase"]
