"""Synthetic QA corpus reproducing the paper's evaluation setup (§3.1–3.2).

Four categories — basics of python programming, technical support related
to network, questions related to order and shipping, customer shopping QA —
with templated generators producing 8,000 unique question/answer pairs for
cache population and 2,000 test queries (500/category). Test queries are a
mix of *paraphrases* of cached questions (lexical substitution, politeness
fillers, clause reordering — the "minor variations" the paper targets) and
*novel* questions drawn from held-out templates, mixed at a ratio chosen to
land in the paper's observed regime (cache hit rates 61.6–68.8%).

Ground truth for the judge: each test query records the ``source_id`` of
the QA pair it paraphrases (-1 for novel queries), so a cache hit is
*positive* iff the matched entry's source equals the query's source — the
offline replacement for the paper's GPT-4o-mini validation (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable

CATEGORIES = (
    "python_basics",
    "network_support",
    "order_shipping",
    "customer_shopping",
)

# --------------------------------------------------------------------------- #
# template banks
# --------------------------------------------------------------------------- #

_PY_TOPICS = [
    "a list", "a dictionary", "a tuple", "a set", "a string", "a dataframe",
    "a generator", "a decorator", "a lambda", "a class", "a module",
    "a virtual environment", "a csv file", "a json file", "an exception",
    "a loop", "a list comprehension", "a regular expression", "a file",
    "a numpy array",
]
_PY_ACTIONS = [
    "reverse", "sort", "copy", "merge", "iterate over", "slice", "filter",
    "create", "delete items from", "find the length of", "convert to a string",
    "append to", "flatten", "deduplicate", "serialize",
]
_PY_TEMPLATES = [
    "how do i {a} {t} in python",
    "what is the best way to {a} {t} in python",
    "python code to {a} {t}",
    "how can i {a} {t} using python",
    "show me how to {a} {t} in python",
]

_NET_DEVICES = [
    "my router", "the wifi", "my modem", "the vpn", "the ethernet connection",
    "my firewall", "the dns server", "the proxy", "my access point",
    "the network printer", "port forwarding", "my ip address",
    "the dhcp server", "my smart tv connection", "the mesh network",
    "the 5ghz band", "my laptop's wifi adapter", "the guest network",
    "the corporate vpn", "the network switch",
]
_NET_ISSUES = [
    "keeps disconnecting", "is very slow", "won't connect", "shows no internet",
    "drops every few minutes", "has high latency", "is not visible",
    "refuses new devices", "times out", "needs to be reset",
    "blocks a website", "fails authentication", "has packet loss",
    "shows limited connectivity", "won't get an ip address",
]
_NET_TEMPLATES = [
    "why {d} {i}",
    "{d} {i} how do i fix it",
    "what should i do when {d} {i}",
    "how to troubleshoot when {d} {i}",
    "help {d} {i}",
]

_ORDER_ITEMS = [
    "my order", "my package", "my shipment", "the delivery", "my parcel",
    "my replacement item", "my return", "my refund", "the exchange",
    "my pre-order", "the backordered item", "my gift order",
    "the express shipment", "my international order", "the second package",
]
_ORDER_ASKS = [
    "where is", "when will i receive", "how do i track", "can i cancel",
    "how do i change the address for", "what is the status of",
    "why is there a delay with", "how do i return", "who delivers",
    "can i expedite", "how long does it take to get", "what happens to",
    "is there an update on", "how do i get a receipt for",
    "can i reschedule the delivery of",
]
_ORDER_TEMPLATES = [
    "{a} {i}",
    "{a} {i} please",
    "i want to know {a2} {i}",
    "could you tell me {a2} {i}",
    "{a} {i} i ordered last week",
]

_SHOP_PRODUCTS = [
    "this phone", "the laptop", "these headphones", "the smart watch",
    "this camera", "the tablet", "the gaming console", "this tv",
    "the vacuum cleaner", "the coffee machine", "this monitor",
    "the keyboard", "the wireless charger", "this speaker", "the printer",
    "the air fryer", "this backpack", "the office chair", "the desk lamp",
    "the fitness tracker",
]
_SHOP_ASKS = [
    "what are the features of", "does a warranty come with", "what colors are available for",
    "is there a discount on", "what is the battery life of", "how much does shipping cost for",
    "can i pay in installments for", "what is the return policy for",
    "are accessories included with", "when will you restock",
    "what are the dimensions of", "is there a student discount for",
    "does it support fast charging,", "what is the weight of",
    "how does it compare to last year's model,",
]
_SHOP_TEMPLATES = [
    "{a} {p}",
    "{a} {p} exactly",
    "hi {a} {p}",
    "quick question {a} {p}",
    "before i buy {a} {p}",
]

# paraphrase machinery ------------------------------------------------------- #

_SYNONYMS = {
    "how do i": ["how can i", "how would i", "what's the way to", "how to"],
    "what is": ["what's", "tell me", "could you explain", "whats"],
    "best way": ["right way", "easiest way", "proper way", "recommended way"],
    "python": ["python 3", "python language", "py"],
    "fix": ["repair", "resolve", "solve", "sort out"],
    "help": ["assist me", "i need help", "support needed", "please help"],
    "why": ["why does", "any idea why", "for what reason"],
    "slow": ["sluggish", "laggy", "really slow"],
    "receive": ["get", "obtain", "have delivered"],
    "order": ["purchase", "buy"],
    "package": ["parcel", "box", "delivery"],
    "track": ["follow", "locate", "trace"],
    "cancel": ["call off", "stop", "void"],
    "features": ["specs", "specifications", "capabilities"],
    "discount": ["deal", "promo", "price cut", "sale"],
    "return": ["send back", "give back"],
    "warranty": ["guarantee", "coverage"],
    "show me": ["give me an example of", "demonstrate", "walk me through"],
    "create": ["make", "build", "construct"],
    "reverse": ["invert", "flip"],
    "sort": ["order", "arrange"],
    "merge": ["combine", "join"],
    "delete": ["remove", "drop"],
    "disconnecting": ["dropping", "cutting out", "losing connection"],
}

_FILLERS_PRE = ["hey", "hi there", "please", "quick question", "hello",
                "excuse me", "urgent", "sorry to bother you"]
_FILLERS_POST = ["thanks", "thank you", "asap please", "any help appreciated",
                 "cheers", "thanks in advance"]


@dataclasses.dataclass(frozen=True)
class QAPair:
    qa_id: int
    category: str
    question: str
    answer: str
    semantic_key: str = ""   # (topic, intent) — two pairs with the same key
                             # have interchangeable answers (judge oracle)


@dataclasses.dataclass(frozen=True)
class TestQuery:
    query: str
    category: str
    source_id: int     # the QA pair this paraphrases; -1 = novel
    semantic_key: str = ""


def _py_gen(rng: random.Random):
    t = rng.choice(_PY_TOPICS)
    a = rng.choice(_PY_ACTIONS)
    tpl = rng.choice(_PY_TEMPLATES)
    q = tpl.format(a=a, t=t)
    ans = f"To {a} {t} in Python, use the standard idiom; e.g. see the docs for {t.split()[-1]}()."
    return q, ans, f"py|{a}|{t}"


def _net_gen(rng: random.Random):
    d = rng.choice(_NET_DEVICES)
    i = rng.choice(_NET_ISSUES)
    tpl = rng.choice(_NET_TEMPLATES)
    q = tpl.format(d=d, i=i)
    ans = f"When {d} {i}, first power-cycle the device, check cabling, then verify configuration."
    return q, ans, f"net|{d}|{i}"


def _order_gen(rng: random.Random):
    i = rng.choice(_ORDER_ITEMS)
    a = rng.choice(_ORDER_ASKS)
    tpl = rng.choice(_ORDER_TEMPLATES)
    q = tpl.format(a=a, i=i, a2=a.replace("?", ""))
    ans = f"Regarding {i}: check the tracking link in your confirmation email or your account's orders page."
    return q, ans, f"ord|{a}|{i}"


def _shop_gen(rng: random.Random):
    p = rng.choice(_SHOP_PRODUCTS)
    a = rng.choice(_SHOP_ASKS)
    tpl = rng.choice(_SHOP_TEMPLATES)
    q = tpl.format(a=a, p=p)
    ans = f"About {p}: full details including {a.split()[-2] if len(a.split())>1 else 'info'} are on the product page; support can confirm specifics."
    return q, ans, f"shop|{a}|{p}"


_GENS: dict[str, Callable] = {
    "python_basics": _py_gen,
    "network_support": _net_gen,
    "order_shipping": _order_gen,
    "customer_shopping": _shop_gen,
}


def paraphrase(question: str, rng: random.Random, strength: float = 0.5) -> str:
    """Minor-variation rewriting (the paper's repeated-query model)."""
    q = question
    # synonym substitutions (longest-match-first)
    for key in sorted(_SYNONYMS, key=len, reverse=True):
        if key in q and rng.random() < strength:
            q = q.replace(key, rng.choice(_SYNONYMS[key]), 1)
    if rng.random() < 0.4:
        q = f"{rng.choice(_FILLERS_PRE)} {q}"
    if rng.random() < 0.3:
        q = f"{q} {rng.choice(_FILLERS_POST)}"
    if rng.random() < 0.2 and ", " in q:
        a, b = q.split(", ", 1)
        q = f"{b}, {a}"
    return q


def build_corpus(n_per_category: int = 2000, seed: int = 0
                 ) -> list[QAPair]:
    """8,000 unique QA pairs (paper §3.1) at the default size."""
    rng = random.Random(seed)
    pairs: list[QAPair] = []
    qa_id = 0
    for cat in CATEGORIES:
        seen = set()
        gen = _GENS[cat]
        attempts = 0
        while len(seen) < n_per_category and attempts < n_per_category * 80:
            q, a, key = gen(rng)
            attempts += 1
            if q in seen:
                continue
            seen.add(q)
            pairs.append(QAPair(qa_id=qa_id, category=cat, question=q,
                                answer=a, semantic_key=key))
            qa_id += 1
    return pairs


_CATEGORY_STRENGTH = {
    # per-category paraphrase aggressiveness, calibrated so threshold-0.8 hit
    # rates land in the paper's Table-1 band (61.6–68.8 %)
    "python_basics": 0.33,
    "network_support": 0.45,
    "order_shipping": 0.45,
    "customer_shopping": 0.75,
}


def build_test_queries(pairs: list[QAPair], n_per_category: int = 500,
                       paraphrase_ratio: float = 0.75, seed: int = 1,
                       strength: float | None = None) -> list[TestQuery]:
    """2,000 test queries (paper §3.2): paraphrases of cached questions mixed
    with novel ones. ``paraphrase_ratio`` controls the ceiling on the hit
    rate; 0.72 lands the system in the paper's 61–69 % band at threshold
    0.8 with the hash embedder (calibrated in benchmarks)."""
    rng = random.Random(seed)
    by_cat: dict[str, list[QAPair]] = {c: [] for c in CATEGORIES}
    for p in pairs:
        by_cat[p.category].append(p)
    known_questions = {p.question for p in pairs}
    queries: list[TestQuery] = []
    for cat in CATEGORIES:
        pool = by_cat[cat]
        for _ in range(n_per_category):
            cat_strength = strength if strength is not None \
                else _CATEGORY_STRENGTH[cat]
            if rng.random() < paraphrase_ratio and pool:
                src = rng.choice(pool)
                q = paraphrase(src.question, rng, cat_strength)
                queries.append(TestQuery(query=q, category=cat,
                                         source_id=src.qa_id,
                                         semantic_key=src.semantic_key))
            else:
                # novel: generate until it's not an exact cached question
                key = ""
                for _ in range(64):
                    q, _a, key = _GENS[cat](rng)
                    q = paraphrase(q, rng, 0.9)   # heavy rewrite
                    if q not in known_questions:
                        break
                queries.append(TestQuery(query=q, category=cat, source_id=-1,
                                         semantic_key=key))
    rng.shuffle(queries)
    return queries
