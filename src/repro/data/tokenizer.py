"""Deterministic offline tokenizer (no network, no learned vocab files).

A word-level signed-hash tokenizer: whitespace/punctuation split, each
token hashed into a fixed id space with a reserved special-token region.
Round-trippable enough for the serving loop (responses are stored as token
ids in the cache slab and detokenized via an id->string side table built
as tokens are first seen — the Redis-value analogue of the paper storing
raw response strings).
"""
from __future__ import annotations

import hashlib
import re

_SPLIT = re.compile(r"\w+|[^\w\s]")

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
UNK_ID = 3
N_SPECIAL = 8


class HashTokenizer:
    """Stateless hashing encoder + stateful (per-instance) decoder table."""

    def __init__(self, vocab_size: int = 32768):
        assert vocab_size > N_SPECIAL * 2
        self.vocab_size = vocab_size
        self._id2str: dict[int, str] = {PAD_ID: "", BOS_ID: "<s>",
                                        EOS_ID: "</s>", UNK_ID: "<unk>"}

    def token_id(self, word: str) -> int:
        h = hashlib.blake2s(word.lower().encode(), digest_size=8).digest()
        tid = N_SPECIAL + int.from_bytes(h, "little") % (self.vocab_size - N_SPECIAL)
        return tid

    def encode(self, text: str, *, bos: bool = True, eos: bool = False,
               max_len: int | None = None) -> list[int]:
        ids = [BOS_ID] if bos else []
        for w in _SPLIT.findall(text):
            tid = self.token_id(w)
            self._id2str.setdefault(tid, w.lower())
            ids.append(tid)
        if eos:
            ids.append(EOS_ID)
        if max_len is not None:
            ids = ids[:max_len]
        return ids

    def decode(self, ids) -> str:
        words = []
        for t in ids:
            t = int(t)
            if t in (PAD_ID, BOS_ID):
                continue
            if t == EOS_ID:
                break
            words.append(self._id2str.get(t, "<unk>"))
        return " ".join(words)

    def encode_batch(self, texts, max_len: int):
        import numpy as np
        out = np.full((len(texts), max_len), PAD_ID, dtype=np.int32)
        lens = np.zeros((len(texts),), dtype=np.int32)
        for i, t in enumerate(texts):
            ids = self.encode(t, max_len=max_len)
            out[i, :len(ids)] = ids
            lens[i] = len(ids)
        return out, lens
