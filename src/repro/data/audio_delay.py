"""MusicGen delay-pattern codec (Copet et al. 2023, §2.2).

EnCodec emits K parallel codebooks per frame; MusicGen's *delay pattern*
offsets codebook k by k steps so a single autoregressive decoder models the
joint distribution: at step t the model predicts codebook k's token for
frame t-k. ``apply_delay``/``remove_delay`` convert between frame-parallel
(B, T, K) token grids and the delayed (B, T+K-1, K) training/serving layout,
padding with ``pad_id``.
"""
from __future__ import annotations

import numpy as np


def apply_delay(tokens: np.ndarray, pad_id: int = 0) -> np.ndarray:
    """(B, T, K) frame-parallel -> (B, T+K-1, K) delayed."""
    b, t, k = tokens.shape
    out = np.full((b, t + k - 1, k), pad_id, dtype=tokens.dtype)
    for cb in range(k):
        out[:, cb:cb + t, cb] = tokens[:, :, cb]
    return out


def remove_delay(delayed: np.ndarray, n_frames: int, pad_id: int = 0
                 ) -> np.ndarray:
    """(B, T+K-1, K) delayed -> (B, T, K) frame-parallel."""
    b, _, k = delayed.shape
    out = np.full((b, n_frames, k), pad_id, dtype=delayed.dtype)
    for cb in range(k):
        out[:, :, cb] = delayed[:, cb:cb + n_frames, cb]
    return out
