"""Per-request tracing for the serving stack (DESIGN.md §18.1–§18.2).

The serving metrics answer *rate* questions ("what fraction of lookups
hit?"); they cannot answer *instance* questions ("why did THIS request
miss?", "which stage owns the p99?"). This module adds the missing layer:
a ``RequestTrace`` of timestamped spans threaded through

    AsyncScheduler.submit -> _form_batch -> _serve
    CachedEngine.serve_batch / process
    llm_backend.generate

with the canonical stage names

    queue_wait      admission queue (arrival -> batch formation)
    coalesce_attach waiter attached to an in-flight duplicate leader
    batch_form      deficit-round-robin micro-batch assembly
    embed           host-side query embedding
    device_step     compiled peek lookup (ANN search + threshold decide)
    near_synthesis  host-side band-row synthesis (§17.3)
    backend_call    LLM backend round-trip for the miss set
    insert          fused commit + masked insert (the second jit dispatch)
    respond         detokenize + judge + metrics + response construction

Engine-side spans are *contiguous* by construction (each stage's end is
the next stage's start), so a trace's span sum reconstructs the measured
end-to-end latency — the property the serve-bench obs stage asserts
(span-sum within 10% of e2e at p50/p95).

Sampling (§18.2) is a *retention* policy, decided when a trace finishes:

  * head      — the first ``head`` traces are always kept (startup bugs);
  * rate      — a deterministic fraction ``sample_rate`` of the rest is
                kept (counter-accumulator, no RNG: reproducible runs);
  * slow      — any trace slower than ``slow_threshold_s`` is kept even
                when the rate sampler would drop it (tail outliers are
                exactly the traces worth keeping);
  * tail      — retained traces live in a ring buffer of ``max_traces``,
                so the *most recent* keepers are always available.

When tracing is **off** (``TraceConfig.off()`` / ``tracer=None`` on the
engine) every hook degenerates to a shared ``_NullTrace`` singleton and a
``None`` stage clock: no per-request allocation, no timestamp calls on
the serve path — the hot path is byte-identical in behaviour to the
pre-observability engine.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque

#: Canonical stage names, in pipeline order. Exported so benchmarks and
#: the exposition render decompositions in a stable order.
STAGES = ("queue_wait", "coalesce_attach", "batch_form", "embed",
          "device_step", "near_synthesis", "backend_call", "insert",
          "respond")


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Trace collection + retention knobs (§18.2)."""

    sample_rate: float = 1.0        # fraction of traces retained (0..1)
    head: int = 8                   # first N traces always retained
    slow_threshold_s: float | None = None   # retain outliers above this
    max_traces: int = 512           # ring capacity for retained traces

    def __post_init__(self):
        if not 0.0 <= self.sample_rate <= 1.0:
            raise ValueError("sample_rate must be within [0, 1]")
        if self.head < 0 or self.max_traces <= 0:
            raise ValueError("head must be >= 0 and max_traces positive")
        if self.slow_threshold_s is not None and self.slow_threshold_s < 0:
            raise ValueError("slow_threshold_s must be >= 0")

    @staticmethod
    def off() -> "TraceConfig":
        """Collection disabled: the serving hot path allocates nothing."""
        return TraceConfig(sample_rate=0.0, head=0, slow_threshold_s=None)

    @property
    def collecting(self) -> bool:
        return (self.sample_rate > 0.0 or self.head > 0
                or self.slow_threshold_s is not None)


@dataclasses.dataclass
class Span:
    """One timestamped stage. ``t0``/``t1`` are perf_counter seconds on
    this process's clock — only differences are meaningful."""

    name: str
    t0: float
    t1: float

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        return {"name": self.name, "t0": round(self.t0, 9),
                "t1": round(self.t1, 9),
                "duration_s": round(self.duration_s, 9)}


class RequestTrace:
    """Spans + attribution for one request's journey through the stack."""

    __slots__ = ("trace_id", "spans", "meta", "e2e_s", "why")

    def __init__(self, trace_id: str):
        self.trace_id = trace_id
        self.spans: list[Span] = []
        self.meta: dict = {}
        self.e2e_s: float | None = None    # measured end-to-end (set by the
                                           # owner at resolution time)
        self.why: dict | None = None       # decision attribution (§18.3)

    def add(self, name: str, t0: float, t1: float) -> None:
        self.spans.append(Span(name, t0, t1))

    def annotate(self, **fields) -> None:
        self.meta.update(fields)

    @property
    def span_sum_s(self) -> float:
        return sum(s.duration_s for s in self.spans)

    def stage_seconds(self) -> dict:
        """name -> summed seconds (a stage may appear once per batch)."""
        out: dict[str, float] = {}
        for s in self.spans:
            out[s.name] = out.get(s.name, 0.0) + s.duration_s
        return out

    def to_dict(self) -> dict:
        d = {"trace_id": self.trace_id,
             "spans": [s.to_dict() for s in self.spans],
             "span_sum_s": round(self.span_sum_s, 9)}
        if self.e2e_s is not None:
            d["e2e_s"] = round(self.e2e_s, 9)
        if self.meta:
            d["meta"] = dict(self.meta)
        if self.why is not None:
            d["why"] = self.why
        return d


class _NullTrace:
    """Shared no-op stand-in when collection is off: every hook is a
    method on ONE module-level singleton — zero per-request allocation."""

    __slots__ = ()
    trace_id = ""
    e2e_s = None
    why = None
    spans: list = []
    meta: dict = {}

    def add(self, name, t0, t1):
        pass

    def annotate(self, **fields):
        pass

    def __bool__(self):
        return False


NULL_TRACE = _NullTrace()


class StageClock:
    """Contiguous stage timing for one batch: ``tick(name)`` closes the
    open stage at ``name`` and opens the next one at the same instant, so
    the recorded spans tile the batch's wall time exactly (no gaps, no
    overlaps — the span-sum invariant)."""

    __slots__ = ("spans", "_t")

    def __init__(self):
        self.spans: list[Span] = []
        self._t = time.perf_counter()

    def tick(self, name: str) -> None:
        t = time.perf_counter()
        self.spans.append(Span(name, self._t, t))
        self._t = t


class Tracer:
    """Owns trace creation, retention sampling and the retained ring."""

    def __init__(self, config: TraceConfig | None = None):
        self.config = config or TraceConfig.off()
        self._seq = itertools.count()
        self._kept_head = 0
        self._acc = 0.0                       # deterministic rate sampler
        self._ring: deque[RequestTrace] = deque(
            maxlen=self.config.max_traces)
        self.started = 0
        self.finished = 0
        self.retained = 0

    @property
    def collecting(self) -> bool:
        return self.config.collecting

    # -- collection ----------------------------------------------------- #
    def start(self, **meta) -> RequestTrace | _NullTrace:
        """New trace, or the shared null trace when collection is off."""
        if not self.config.collecting:
            return NULL_TRACE
        self.started += 1
        t = RequestTrace(f"rt-{next(self._seq):08d}")
        if meta:
            t.meta.update(meta)
        return t

    def stage_clock(self) -> StageClock | None:
        """Per-batch stage clock; None (no timestamp calls) when off."""
        return StageClock() if self.config.collecting else None

    # -- retention ------------------------------------------------------ #
    def _keep(self, trace: RequestTrace) -> bool:
        if self._kept_head < self.config.head:
            self._kept_head += 1
            return True
        slow = self.config.slow_threshold_s
        if slow is not None and (trace.e2e_s or trace.span_sum_s) >= slow:
            return True
        self._acc += self.config.sample_rate
        if self._acc >= 1.0:
            self._acc -= 1.0
            return True
        return False

    def finish(self, trace: RequestTrace | _NullTrace,
               e2e_s: float | None = None) -> None:
        """Close a trace; the retention policy decides whether it lives."""
        if not trace:                          # null trace: off path
            return
        if e2e_s is not None:
            trace.e2e_s = e2e_s
        self.finished += 1
        if self._keep(trace):
            self.retained += 1
            self._ring.append(trace)

    # -- read side ------------------------------------------------------ #
    def traces(self) -> list[RequestTrace]:
        return list(self._ring)

    def drain(self) -> list[dict]:
        """Retained traces as dicts, clearing the ring."""
        out = [t.to_dict() for t in self._ring]
        self._ring.clear()
        return out

    def stage_decomposition(self) -> dict:
        """Per-stage latency decomposition over the retained traces:
        ``{stage: {count, p50_s, p95_s, p99_s, total_s}}`` in pipeline
        order — the per-stage breakdown the serve-bench obs stage and the
        ``/metrics`` exposition report."""
        from repro.serving.metrics import percentiles
        by_stage: dict[str, list[float]] = {}
        for t in self._ring:
            for name, secs in t.stage_seconds().items():
                by_stage.setdefault(name, []).append(secs)
        out = {}
        for name in STAGES:
            if name in by_stage:
                xs = by_stage.pop(name)
                row = percentiles(xs)
                row["total_s"] = round(sum(xs), 6)
                out[name] = row
        for name, xs in sorted(by_stage.items()):   # non-canonical stages
            row = percentiles(xs)
            row["total_s"] = round(sum(xs), 6)
            out[name] = row
        return out
