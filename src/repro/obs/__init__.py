"""Observability plane: request tracing, decision attribution, export.

Three modules, one per question the aggregate metrics can't answer:

  * :mod:`repro.obs.trace`   — "which stage owns the p99?"
  * :mod:`repro.obs.explain` — "why did THIS request miss?"
  * :mod:`repro.obs.export`  — "what is the stack doing right now?"

See DESIGN.md §18.
"""
from repro.obs.explain import build_why, effective_edges
from repro.obs.export import (EventLog, MetricsExporter, REQUIRED_FAMILIES,
                              prometheus_text)
from repro.obs.trace import (NULL_TRACE, STAGES, RequestTrace, Span,
                             StageClock, TraceConfig, Tracer)

__all__ = [
    "NULL_TRACE", "STAGES", "RequestTrace", "Span", "StageClock",
    "TraceConfig", "Tracer", "build_why", "effective_edges", "EventLog",
    "MetricsExporter", "REQUIRED_FAMILIES", "prometheus_text",
]
