"""Decision attribution: the structured ``why`` record (DESIGN.md §18.3).

Every quantity a cache decision depends on already crosses the device
seam in ``LookupResult`` and the runtime's policy/partition state — this
module just collects them into one JSON-able record per request instead
of letting them evaporate after the batch:

    {"decision": "near_hit",
     "score": 0.787, "matched_slot": 1042, "matched_source_id": 17,
     "effective_threshold": 0.8, "threshold_source": "policy",
     "band": {"lo": 0.75, "hi": 0.8, "lo_source": "tenant"},
     "topk": [{"slot": 1042, "score": 0.787, "source_id": 17}, ...],
     "session_fused": false, "tenant": "acme",
     "synthesis": {"verdict": "served", "source_id": 17},
     "coalesced_into": null}

``decision`` is one of ``hit`` / ``near_hit`` / ``miss`` (and the
scheduler rewrites it to ``coalesced`` for waiters, filling
``coalesced_into`` with the leader's coalesce key). ``threshold_source``
/ ``lo_source`` say which layer supplied the edge (``policy`` vs
``tenant`` override) — the first question a per-tenant threshold bug
raises. The record is host-side only and built from arrays the engine
already pulled off the device for the response path, so attribution
costs no extra device round-trip.
"""
from __future__ import annotations

import numpy as np


def effective_edges(policy, policy_state, partition, tenant_ix: int | None
                    ) -> dict:
    """Resolve the decision edges a given row was judged against.

    Returns ``{"threshold", "threshold_source", "band"}`` where ``band``
    is ``None`` for band-less policies and otherwise
    ``{"lo", "hi", "lo_source"}`` — mirroring exactly the override order
    the compiled step applies (§13.2 thresholds, §17.2 band_lo): tenant
    override wins when set (sentinel < 0 = none), policy state otherwise.
    """
    ps = np.asarray(policy_state, dtype=np.float32).reshape(-1)
    banded = hasattr(policy, "near")
    # policy-state layout: FixedThreshold/AdaptiveThreshold carry the
    # effective hit threshold first; BandPolicy carries [tau_lo, tau_hi,..]
    tau_hit = float(ps[1]) if banded else float(ps[0])
    tau_lo = float(ps[0]) if banded else None
    source = "policy"
    lo_source = "policy"
    if partition is not None and tenant_ix is not None:
        thr = float(np.asarray(partition.thresholds_array())[tenant_ix])
        if thr >= 0.0:
            tau_hit, source = thr, "tenant"
        if banded:
            lo = float(np.asarray(partition.band_lo_array())[tenant_ix])
            if lo >= 0.0:
                tau_lo, lo_source = lo, "tenant"
    band = None
    if banded:
        band = {"lo": round(tau_lo, 6), "hi": round(tau_hit, 6),
                "lo_source": lo_source}
    return {"threshold": round(tau_hit, 6), "threshold_source": source,
            "band": band}


def build_why(row: int, *, request, hit: bool, near_served: bool,
              score: float, matched_slot: int, matched_source_id: int,
              topk_slots, topk_scores, topk_source_ids,
              edges: dict, session_fused: bool,
              synthesizer_present: bool, near_band: bool,
              synthesis_source_id: int | None) -> dict:
    """One request's decision record from batch-level arrays (§18.3)."""
    if hit:
        decision = "hit"
    elif near_served:
        decision = "near_hit"
    else:
        decision = "miss"
    topk = [{"slot": int(topk_slots[j]),
             "score": round(float(topk_scores[j]), 6),
             "source_id": int(topk_source_ids[j])}
            for j in range(len(topk_slots)) if int(topk_slots[j]) >= 0]
    synthesis = None
    if synthesizer_present and near_band:
        synthesis = {
            "verdict": "served" if near_served else "abstained",
            "source_id": (int(synthesis_source_id)
                          if near_served and synthesis_source_id is not None
                          else None),
        }
    return {
        "row": int(row),
        "decision": decision,
        "score": round(float(score), 6),
        "matched_slot": int(matched_slot) if score > -np.inf else -1,
        "matched_source_id": int(matched_source_id)
        if score > -np.inf else -1,
        "effective_threshold": edges["threshold"],
        "threshold_source": edges["threshold_source"],
        "band": edges["band"],
        "in_band": bool(near_band),
        "topk": topk,
        "session_fused": bool(session_fused),
        "tenant": request.tenant,
        "session": request.session,
        "synthesis": synthesis,
        "coalesced_into": None,
    }
