"""Metrics export plane: event ring + Prometheus-style text exposition
(DESIGN.md §18.4).

Two complementary drains for the numbers the stack already keeps:

  * ``EventLog`` — a ring-buffered structured event stream (one dict per
    serve step: batch size, hits, near-hits, backend calls, stage times
    and the per-step ``CacheStats`` delta). Bounded by construction
    (``deque(maxlen=...)``) and drained as JSON lines — the greppable
    "what happened, in order" record that aggregate counters destroy.

  * ``prometheus_text`` / ``MetricsExporter`` — a text exposition in the
    Prometheus 0.0.4 format (``# HELP`` / ``# TYPE`` + samples) derived
    from ``ServingMetrics`` (host-side, incl. per-tenant labels), the
    device-side ``CacheStats``/``TenancyState`` counters, and the
    tracer's per-stage latency decomposition. Served from ``GET
    /metrics`` on the TCP front-end and from ``repro.launch.serve
    --metrics-port``; any Prometheus-compatible scraper can poll it.

No third-party client library (the repo's offline constraint): the
format is plain text and the histogram/summary conventions are followed
by hand — cumulative ``le`` buckets, ``_sum``/``_count`` rows, labeled
quantile gauges.
"""
from __future__ import annotations

import itertools
import json
import time
from collections import deque

#: Metric families the exposition always emits (CI's scrape assertion and
#: the serve-bench smoke validate against this list, so it is the contract).
REQUIRED_FAMILIES = (
    "repro_queries_total",
    "repro_coalesced_requests_total",
    "repro_lookups_total",
    "repro_cache_hits_total",
    "repro_latency_seconds",
    "repro_latency_quantile_seconds",
    "repro_cost_usd_total",
    "repro_slab_lookups_total",
    "repro_slab_inserts_total",
    "repro_backend_retries_total",
    "repro_breaker_transitions_total",
    "repro_degraded_served_total",
)


class EventLog:
    """Bounded structured event ring with a JSON-lines drain (§18.4)."""

    def __init__(self, capacity: int = 2048):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self._seq = itertools.count()
        self._ring: deque[dict] = deque(maxlen=capacity)
        self.emitted = 0                     # total ever (ring holds a tail)

    def emit(self, kind: str, **fields) -> dict:
        ev = {"seq": next(self._seq), "ts": time.time(), "kind": kind}
        ev.update(fields)
        self._ring.append(ev)
        self.emitted += 1
        return ev

    def __len__(self) -> int:
        return len(self._ring)

    def events(self) -> list[dict]:
        return list(self._ring)

    def to_jsonl(self) -> str:
        return "".join(json.dumps(ev, sort_keys=True) + "\n"
                       for ev in self._ring)

    def drain(self) -> list[dict]:
        out = list(self._ring)
        self._ring.clear()
        return out


# --------------------------------------------------------------------- #
# Prometheus-style text exposition
# --------------------------------------------------------------------- #
def _esc(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace(
        "\n", r"\n")


def _fmt(value) -> str:
    f = float(value)
    if f == float("inf"):
        return "+Inf"
    return repr(round(f, 9)) if isinstance(value, float) else str(value)


class _Lines:
    """Accumulates one exposition document, one family at a time."""

    def __init__(self):
        self.lines: list[str] = []

    def family(self, name: str, kind: str, help_text: str) -> None:
        self.lines.append(f"# HELP {name} {help_text}")
        self.lines.append(f"# TYPE {name} {kind}")

    def sample(self, name: str, labels: dict | None, value) -> None:
        lab = ""
        if labels:
            inner = ",".join(f'{k}="{_esc(v)}"' for k, v in labels.items())
            lab = "{" + inner + "}"
        self.lines.append(f"{name}{lab} {_fmt(value)}")

    def text(self) -> str:
        return "\n".join(self.lines) + "\n"


def _latency_families(out: _Lines, samples: dict, *, extra_labels: dict):
    """Histogram + quantile rows for one ``path -> LatencyReservoir`` map."""
    for path, res in sorted(samples.items()):
        labels = {**extra_labels, "path": path}
        cum = 0
        for le, n in res.bucket_rows():
            cum += n
            out.sample("repro_latency_seconds_bucket",
                       {**labels, "le": _fmt(le)}, cum)
        out.sample("repro_latency_seconds_sum", labels, res.total_s)
        out.sample("repro_latency_seconds_count", labels, res.count)


def _quantile_rows(out: _Lines, family: str, samples: dict, *,
                   extra_labels: dict):
    for path, res in sorted(samples.items()):
        row = res.summary()
        for q, key in (("0.5", "p50_s"), ("0.95", "p95_s"),
                       ("0.99", "p99_s")):
            out.sample(family,
                       {**extra_labels, "path": path, "quantile": q},
                       row[key])


def prometheus_text(metrics, *, cache_stats=None, tenant_stats=None,
                    tracer=None, capacity: int | None = None,
                    breaker=None) -> str:
    """Render one scrape of the serving stack.

    ``metrics`` is a ``ServingMetrics``; the rest are optional extra
    planes: ``cache_stats`` the device ``CacheStats``, ``tenant_stats``
    the ``CachedEngine.tenant_stats()`` dict, ``tracer`` a
    ``repro.obs.Tracer`` (adds the per-stage decomposition), ``capacity``
    the slab capacity gauge, ``breaker`` the engine's ``CircuitBreaker``
    (adds the live state gauge; the transition counters are emitted
    unconditionally — zeros without one — per REQUIRED_FAMILIES).
    """
    out = _Lines()
    s = metrics  # host-side ServingMetrics

    out.family("repro_queries_total", "counter",
               "Requests that paid their own lookup (pads excluded).")
    out.sample("repro_queries_total", None, s.queries)

    out.family("repro_coalesced_requests_total", "counter",
               "Requests merged into an in-flight duplicate leader.")
    out.sample("repro_coalesced_requests_total", None, s.coalesced_calls)

    out.family("repro_lookups_total", "counter",
               "Cache lookups by request category.")
    for cat, m in sorted(s.per_category.items()):
        out.sample("repro_lookups_total", {"category": cat}, m.lookups)
    out.family("repro_cache_hits_total", "counter",
               "Cache hits by request category.")
    for cat, m in sorted(s.per_category.items()):
        out.sample("repro_cache_hits_total", {"category": cat}, m.hits)
    out.family("repro_positive_hits_total", "counter",
               "Judge-confirmed hits by request category.")
    for cat, m in sorted(s.per_category.items()):
        out.sample("repro_positive_hits_total", {"category": cat},
                   m.positive_hits)

    out.family("repro_cost_usd_total", "counter",
               "LLM spend with the cache in front.")
    out.sample("repro_cost_usd_total", None, s.total_cost_usd)
    out.family("repro_baseline_cost_usd_total", "counter",
               "What 100% backend calls would have cost.")
    out.sample("repro_baseline_cost_usd_total", None, s.baseline_cost_usd)

    # end-to-end latency: histogram (+Inf-terminated cumulative buckets)
    # and p50/p95/p99 quantile gauges per path
    out.family("repro_latency_seconds", "histogram",
               "End-to-end request latency by serve path.")
    _latency_families(out, s.latency_samples, extra_labels={})
    out.family("repro_latency_quantile_seconds", "gauge",
               "End-to-end latency quantiles by serve path.")
    _quantile_rows(out, "repro_latency_quantile_seconds",
                   s.latency_samples, extra_labels={})

    # per-tenant plane (host-side): the labels multi-tenant dashboards cut by
    if s.per_tenant:
        out.family("repro_tenant_lookups_total", "counter",
                   "Lookups by tenant (host-side accounting).")
        for name, t in sorted(s.per_tenant.items()):
            out.sample("repro_tenant_lookups_total", {"tenant": name},
                       t.lookups)
        out.family("repro_tenant_hits_total", "counter",
                   "Cache hits by tenant.")
        for name, t in sorted(s.per_tenant.items()):
            out.sample("repro_tenant_hits_total", {"tenant": name}, t.hits)
        out.family("repro_tenant_coalesced_total", "counter",
                   "Coalesced requests by tenant.")
        for name, t in sorted(s.per_tenant.items()):
            out.sample("repro_tenant_coalesced_total", {"tenant": name},
                       t.coalesced)
        out.family("repro_tenant_latency_quantile_seconds", "gauge",
                   "Latency quantiles by tenant and serve path.")
        for name, t in sorted(s.per_tenant.items()):
            _quantile_rows(out, "repro_tenant_latency_quantile_seconds",
                           t.latency_samples,
                           extra_labels={"tenant": name})

    # context / near planes (only once the engine recorded them)
    if s.context_seen:
        out.family("repro_context_lookups_total", "counter",
                   "Lookups split by context-fused vs single-turn rows.")
        for bucket, m in (("context", s.context),
                          ("single_turn", s.single_turn)):
            out.sample("repro_context_lookups_total", {"bucket": bucket},
                       m.lookups)
        out.family("repro_context_hits_total", "counter",
                   "Hits split by context-fused vs single-turn rows.")
        for bucket, m in (("context", s.context),
                          ("single_turn", s.single_turn)):
            out.sample("repro_context_hits_total", {"bucket": bucket},
                       m.hits)
    if s.near_seen:
        out.family("repro_near_band_total", "counter",
                   "Lookups scoring inside the [tau_lo, tau_hi) band.")
        out.sample("repro_near_band_total", None, s.near.band)
        out.family("repro_near_served_total", "counter",
                   "Band rows the synthesizer converted.")
        out.sample("repro_near_served_total", None, s.near.served)
        out.family("repro_near_precision", "gauge",
                   "Judge-confirmed precision of served near-hits.")
        out.sample("repro_near_precision", None, s.near.precision)

    # resilience plane (§20.5): the retry/breaker/degraded families are
    # contractual (REQUIRED_FAMILIES) — emitted on every scrape, zeros on
    # a fault-free or resilience-less deployment, so dashboards and
    # alerting rules never see a family appear mid-incident
    r = s.resilience
    out.family("repro_backend_retries_total", "counter",
               "Backend retry attempts after a failed call.")
    out.sample("repro_backend_retries_total", None, r.retries)
    out.family("repro_backend_failures_total", "counter",
               "Failed backend calls (including failed retries).")
    out.sample("repro_backend_failures_total", None, r.backend_failures)
    out.family("repro_degraded_served_total", "counter",
               "Misses served from a cached neighbour in degraded mode.")
    out.sample("repro_degraded_served_total", None, r.degraded_served)
    out.family("repro_overload_shed_total", "counter",
               "Requests rejected with Overloaded by the shed policy.")
    out.sample("repro_overload_shed_total", None, r.shed)
    out.family("repro_deadline_exhausted_total", "counter",
               "Miss rows whose deadline budget expired before an answer.")
    out.sample("repro_deadline_exhausted_total", None, r.deadline_exhausted)
    out.family("repro_breaker_transitions_total", "counter",
               "Circuit breaker transitions by kind.")
    out.sample("repro_breaker_transitions_total", {"transition": "trip"},
               0 if breaker is None else breaker.trips)
    out.sample("repro_breaker_transitions_total", {"transition": "recover"},
               0 if breaker is None else breaker.recoveries)
    if breaker is not None:
        out.family("repro_breaker_state", "gauge",
                   "Breaker state: 0 closed, 1 half-open, 2 open.")
        out.sample("repro_breaker_state", None,
                   {"closed": 0, "half_open": 1, "open": 2}[breaker.state])
        out.family("repro_breaker_short_circuits_total", "counter",
                   "Calls refused by the open breaker.")
        out.sample("repro_breaker_short_circuits_total", None,
                   breaker.short_circuits)

    # device-side plane: the compiled step's own counters
    if cache_stats is not None:
        out.family("repro_slab_lookups_total", "counter",
                   "Device-side lookups (CacheStats).")
        out.sample("repro_slab_lookups_total", None,
                   int(cache_stats.lookups))
        out.family("repro_slab_hits_total", "counter",
                   "Device-side hits (CacheStats).")
        out.sample("repro_slab_hits_total", None, int(cache_stats.hits))
        out.family("repro_slab_inserts_total", "counter",
                   "Device-side inserts (CacheStats).")
        out.sample("repro_slab_inserts_total", None,
                   int(cache_stats.inserts))
        out.family("repro_slab_expired_evictions_total", "counter",
                   "Entries dropped by TTL expiry (CacheStats).")
        out.sample("repro_slab_expired_evictions_total", None,
                   int(cache_stats.expired_evictions))
    else:
        # the families are contractual (REQUIRED_FAMILIES): emit zeros so
        # a scraper never sees a family appear/disappear between scrapes
        out.family("repro_slab_lookups_total", "counter",
                   "Device-side lookups (CacheStats).")
        out.sample("repro_slab_lookups_total", None, 0)
        out.family("repro_slab_inserts_total", "counter",
                   "Device-side inserts (CacheStats).")
        out.sample("repro_slab_inserts_total", None, 0)
    if capacity is not None:
        out.family("repro_slab_capacity", "gauge", "Slab slot capacity.")
        out.sample("repro_slab_capacity", None, capacity)

    # device-side per-tenant counters (TenancyState via tenant_stats())
    if tenant_stats:
        out.family("repro_tenant_slab_inserts_total", "counter",
                   "Device-side inserts by tenant (TenancyState).")
        for name, row in sorted(tenant_stats.items()):
            out.sample("repro_tenant_slab_inserts_total", {"tenant": name},
                       row["inserts"])
        out.family("repro_tenant_slab_evictions_total", "counter",
                   "Device-side evictions by tenant (TenancyState).")
        for name, row in sorted(tenant_stats.items()):
            out.sample("repro_tenant_slab_evictions_total",
                       {"tenant": name}, row["evictions"])

    # trace plane: retained-trace counters + per-stage decomposition
    if tracer is not None:
        out.family("repro_traces_retained_total", "counter",
                   "Traces retained by the sampling policy.")
        out.sample("repro_traces_retained_total", None, tracer.retained)
        out.family("repro_traces_finished_total", "counter",
                   "Traces finished (retained or dropped).")
        out.sample("repro_traces_finished_total", None, tracer.finished)
        decomp = tracer.stage_decomposition()
        if decomp:
            out.family("repro_trace_stage_seconds", "gauge",
                       "Per-stage latency quantiles over retained traces.")
            for stage, row in decomp.items():
                for q, key in (("0.5", "p50_s"), ("0.95", "p95_s"),
                               ("0.99", "p99_s")):
                    out.sample("repro_trace_stage_seconds",
                               {"stage": stage, "quantile": q}, row[key])

    return out.text()


class MetricsExporter:
    """Bind the exposition to one engine (the `/metrics` route handler)."""

    def __init__(self, engine):
        self.engine = engine

    def render(self) -> str:
        eng = self.engine
        res = getattr(eng, "resilience", None)
        return prometheus_text(
            eng.metrics,
            cache_stats=eng.stats,
            tenant_stats=eng.tenant_stats() if eng.registry is not None
            else None,
            tracer=eng.tracer,
            capacity=eng.cache.config.capacity,
            breaker=None if res is None else res.breaker)
