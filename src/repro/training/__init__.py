"""Training substrate: AdamW, schedules, checkpointing, train loop."""
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_update,
                                      init_adamw, lr_at, make_train_step,
                                      global_norm)
from repro.training.checkpoint import (CheckpointCorruptError,
                                       load_checkpoint, open_checkpoint,
                                       save_checkpoint)

__all__ = ["AdamWConfig", "AdamWState", "adamw_update", "init_adamw",
           "lr_at", "make_train_step", "global_norm", "load_checkpoint",
           "save_checkpoint", "open_checkpoint", "CheckpointCorruptError"]
