"""AdamW + cosine/linear-warmup schedule, pure JAX (no optax dependency).

The optimizer state mirrors the param pytree (m, v) and updates are
elementwise — trivially pjit-shardable with the same PartitionSpecs as the
parameters (first/second moments inherit the param sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: Array
    m: Any
    v: Any


def init_adamw(params: Any) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros))


def lr_at(cfg: AdamWConfig, step: Array) -> Array:
    """Linear warmup then cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree: Any) -> Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any, state: AdamWState
                 ) -> tuple[Any, AdamWState, dict]:
    """One AdamW step with global-norm clipping. Returns (params, state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:   # decay matrices only (norms/embeddings-1d skip)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics


def make_train_step(loss_fn: Callable, cfg: AdamWConfig):
    """loss_fn(params, batch) -> scalar. Returns jit-able step fn."""

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, metrics = adamw_update(cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step
