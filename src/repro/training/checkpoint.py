"""Functional checkpointing: params/opt-state/cache-slab to flat .npz.

Pytrees are flattened with '/'-joined key paths (dataclasses and dicts),
saved as one compressed npz plus a tiny JSON manifest — restartable,
inspectable, no framework lock-in. Cache slabs (the Redis analogue) are
checkpointed with the same machinery, giving the paper's "cache persists
across restarts" behaviour for free.

Crash safety (DESIGN.md §20.6): both the npz and the manifest are written
to a temp file in the target directory and published with ``os.replace``
(atomic on POSIX), so a crash mid-save leaves the previous snapshot
intact — never a half-written file under the real name. On the read side
every load goes through ``open_checkpoint``, which reads every member
eagerly and converts the zoo of zipfile/np.load failure modes a truncated
or corrupt file produces into one loud ``CheckpointCorruptError`` naming
the path.
"""
from __future__ import annotations

import json
import os
import zipfile
from typing import Any

import jax
import numpy as np


class CheckpointCorruptError(RuntimeError):
    """The checkpoint file exists but cannot be read back — truncated
    write, bit rot, or not an npz at all. The snapshot must be discarded;
    retrying the load cannot succeed."""


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves_with_paths:
        key = "/".join(_key_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "name"):
        return str(p.name)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(path: str, tree: Any, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    # write-then-replace (§20.6): np.savez appends ".npz" to bare string
    # paths but not to file objects, so write the temp through a handle and
    # publish both files atomically under their real names
    data_path = path if path.endswith(".npz") else path + ".npz"
    tmp_data = data_path + ".tmp"
    try:
        with open(tmp_data, "wb") as f:
            np.savez_compressed(f, **flat)
        os.replace(tmp_data, data_path)
    finally:
        if os.path.exists(tmp_data):
            os.remove(tmp_data)
    manifest = {"keys": sorted(flat), "metadata": metadata or {}}
    tmp_manifest = path + ".manifest.json.tmp"
    try:
        with open(tmp_manifest, "w") as f:
            json.dump(manifest, f, indent=2)
        os.replace(tmp_manifest, path + ".manifest.json")
    finally:
        if os.path.exists(tmp_manifest):
            os.remove(tmp_manifest)


def open_checkpoint(path: str) -> dict[str, np.ndarray]:
    """Corrupt-safe checkpoint read: every member loaded eagerly.

    A truncated npz can fail at open time (broken zip directory) OR only
    when a member is decompressed (the central directory survived but the
    data didn't), and the raw failure is any of BadZipFile / OSError /
    EOFError / ValueError deep inside np.load. Reading everything here
    turns all of those into one ``CheckpointCorruptError`` that names the
    file, BEFORE any caller starts mutating its own state.
    """
    data_path = path if path.endswith(".npz") else path + ".npz"
    try:
        with np.load(data_path) as data:
            return {k: np.asarray(data[k]) for k in data.files}
    except (zipfile.BadZipFile, OSError, EOFError, ValueError, KeyError) as exc:
        raise CheckpointCorruptError(
            f"checkpoint {data_path!r} is truncated or corrupt "
            f"({type(exc).__name__}: {exc}); the snapshot cannot be "
            "restored — delete it and fall back to an older one") from exc


def load_checkpoint(path: str, template: Any) -> Any:
    """Restore into the structure of ``template`` (shapes must match)."""
    data = open_checkpoint(path)
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for p, leaf in leaves_with_paths:
        key = "/".join(_key_str(x) for x in p)
        if key not in data:
            raise CheckpointCorruptError(
                f"checkpoint {path!r} is missing key {key!r} required by "
                "the restore template")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def load_checkpoint_flat(path: str) -> dict[str, np.ndarray]:
    """Raw key -> array view of a checkpoint, no template required — the
    entry point for cross-layout restores where the saved tree's structure
    (per-shard tenancy / index leaves) differs from the running one."""
    return open_checkpoint(path)


def reshard_runtime(flat: dict[str, np.ndarray], template: Any, *,
                    old_shards: int, new_shards: int, partition=None,
                    prefix: str = "runtime") -> Any:
    """Restore a checkpointed ``CacheRuntime`` onto a *different* shard
    count (DESIGN.md §19.5).

    The slab arrays keep their global shapes across layouts — only the
    entry *placement* (which global row a logical entry occupies under the
    shard-major round-robin convention), the per-shard ``TenancyState``
    leaves and the per-shard index state change. Host-side algorithm:

      1. extract live entries and order them globally by
         ``(inserted_at, slot)`` — the FIFO total order every ring agrees
         on;
      2. re-place them round-robin into the new layout (per tenant ring
         when partitioned: the tenant of an old entry is derived from its
         *local* offset via the old layout's per-shard region map);
      3. rebuild ring pointers from the placement counts; re-attribute
         summed tenancy counters onto shard 0 (the layout the sharded
         step's sum-reduce expects); advance the insert clock to the
         number of entries placed;
      4. keep ``template``'s fresh index state — callers must schedule a
         refit (the absorbed bucket contents refer to old-placement local
         slot ids).

    ``template`` must be a freshly initialized runtime of the NEW layout;
    ``partition`` is the *global* PartitionMap (None when single-tenant).
    Stats / policy / fusion leaves are replicated in every layout and copy
    through shape-checked by name.
    """
    import dataclasses

    import jax.numpy as jnp

    skey = prefix + "/state/"
    g = {k[len(skey):]: np.asarray(v) for k, v in flat.items()
         if k.startswith(skey)}
    n = int(g["valid"].shape[0])
    if n % old_shards or n % new_shards:
        raise ValueError(f"capacity {n} not divisible by shard counts "
                         f"{old_shards} -> {new_shards}")
    l_old, l_new = n // old_shards, n // new_shards
    live = np.nonzero(g["valid"].astype(bool))[0]
    order = live[np.lexsort((live, g["inserted_at"][live]))]
    e = int(order.shape[0])

    tenancy = template.tenancy
    if partition is None:
        r = np.arange(e)
        dst = (r % new_shards) * l_new + r // new_shards
    else:
        sizes = np.asarray(partition.sizes, dtype=np.int64)
        if np.any(sizes % old_shards) or np.any(sizes % new_shards):
            raise ValueError(f"region sizes {partition.sizes} must divide "
                             f"both shard counts {old_shards}, {new_shards}")
        old_edges = np.cumsum(sizes // old_shards)
        new_sizes = sizes // new_shards
        new_starts = np.asarray(partition.starts, dtype=np.int64) \
            // new_shards
        owner = np.searchsorted(old_edges, order % l_old, side="right")
        dst = np.empty((e,), dtype=np.int64)
        t_count = np.zeros((len(partition),), dtype=np.int64)
        for t in range(len(partition)):
            idx = np.nonzero(owner == t)[0]      # already in FIFO order
            r = np.arange(idx.size)
            dst[idx] = ((r % new_shards) * l_new + new_starts[t]
                        + r // new_shards)
            t_count[t] = idx.size
        s_idx = np.arange(new_shards)[:, None]
        fill = np.maximum(t_count[None, :] - s_idx, 0)
        fill = -(-fill // new_shards)            # ceil div
        ptr = (fill % new_sizes[None, :]).astype(np.int32)

        def _total(name: str) -> np.ndarray:
            arr = np.asarray(flat[f"{prefix}/tenancy/{name}"])
            return arr.reshape(-1, arr.shape[-1]).sum(axis=0)

        def _attr(name: str) -> jnp.ndarray:
            tot = _total(name).astype(np.int32)
            if new_shards == 1:
                return jnp.asarray(tot)
            out = np.zeros((new_shards, tot.shape[0]), dtype=np.int32)
            out[0] = tot                          # sum-reduce stays exact
            return jnp.asarray(out)

        tenancy = dataclasses.replace(
            template.tenancy,
            ptr=jnp.asarray(ptr if new_shards > 1 else ptr[0]),
            lookups=_attr("lookups"), hits=_attr("hits"),
            inserts=_attr("inserts"), evictions=_attr("evictions"))

    fields = {}
    for name, arr in g.items():
        tmpl = getattr(template.state, name)
        if arr.ndim == 0 or arr.shape[0] != n:
            continue                              # clock scalars, below
        out = np.array(tmpl)
        out[dst] = arr[order]
        fields[name] = jnp.asarray(out, dtype=tmpl.dtype)
    ring_local = partition is None and new_shards == 1
    state = dataclasses.replace(
        template.state,
        ptr=jnp.asarray(e % n if ring_local else 0, dtype=jnp.int32),
        n_inserts=jnp.asarray(e, dtype=jnp.int32), **fields)

    def _copy_group(sub: Any, name: str) -> Any:
        if sub is None:
            return None
        lp, td = jax.tree_util.tree_flatten_with_path(sub)
        leaves = []
        for p, leaf in lp:
            tail = "/".join(_key_str(x) for x in p)
            key = f"{prefix}/{name}/{tail}" if tail else f"{prefix}/{name}"
            arr = flat.get(key)
            if arr is not None and tuple(np.shape(arr)) == tuple(leaf.shape):
                leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
            else:
                leaves.append(leaf)
        return jax.tree_util.tree_unflatten(td, leaves)

    return template.replace(
        state=state, tenancy=tenancy,
        stats=_copy_group(template.stats, "stats"),
        policy_state=_copy_group(template.policy_state, "policy_state"),
        fusion=_copy_group(template.fusion, "fusion"))
