"""Functional checkpointing: params/opt-state/cache-slab to flat .npz.

Pytrees are flattened with '/'-joined key paths (dataclasses and dicts),
saved as one compressed npz plus a tiny JSON manifest — restartable,
inspectable, no framework lock-in. Cache slabs (the Redis analogue) are
checkpointed with the same machinery, giving the paper's "cache persists
across restarts" behaviour for free.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves_with_paths:
        key = "/".join(_key_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "name"):
        return str(p.name)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(path: str, tree: Any, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez_compressed(path, **flat)
    manifest = {"keys": sorted(flat), "metadata": metadata or {}}
    with open(path + ".manifest.json", "w") as f:
        json.dump(manifest, f, indent=2)


def load_checkpoint(path: str, template: Any) -> Any:
    """Restore into the structure of ``template`` (shapes must match)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for p, leaf in leaves_with_paths:
        key = "/".join(_key_str(x) for x in p)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
